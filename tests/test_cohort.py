"""Cohort-virtualized federation tests: non-IID partitioners, the
CohortStore flat-buffer gather/scatter, participation schedulers, and the
staleness-aware combiners.  The C == U bitwise pins against the plain
fused engine live in tests/test_engine.py."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.approaches import (DistGANConfig, d_flat_layout,
                                   d_opt_flat_layout, init_state)
from repro.core.federated import (COMBINERS, cohort_gather, cohort_scatter,
                                  combine_staleness_max_abs,
                                  combine_staleness_mean, make_cohort_store,
                                  make_schedule, participation_weights,
                                  upload_bytes_flat)
from repro.core.gan import MLPGanConfig, make_mlp_pair
from repro.core.protocol import run_distgan
from repro.data.federated import (FederatedDataset, dirichlet_partition,
                                  quantity_skew_partition)
from repro.data.mixtures import make_user_domains

PAIR = make_mlp_pair(MLPGanConfig(data_dim=2, z_dim=8, g_hidden=32,
                                  d_hidden=32))


def _toy_labeled(n=600, n_classes=6):
    rng = np.random.default_rng(0)
    labels = rng.integers(0, n_classes, size=n)
    data = (labels[:, None] + rng.normal(0, 0.1, (n, 3))).astype(np.float32)
    return data, labels


# ---------------------------------------------------------------------------
# non-IID partitioners
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("alpha", [0.05, 0.5, 5.0])
def test_dirichlet_partition_no_empty_shards_and_meta(alpha):
    data, labels = _toy_labeled()
    ds = dirichlet_partition(data, labels, num_users=8, alpha=alpha, seed=3)
    assert ds.num_users == 8
    sizes = ds.meta["shard_sizes"]
    assert len(sizes) == 8 and min(sizes) >= 1
    assert sum(sizes) == len(data)
    assert ds.meta["partition"] == "dirichlet"
    assert ds.meta["alpha"] == alpha
    # samplers actually draw from non-empty shards
    rng = np.random.default_rng(0)
    for u in range(8):
        assert ds.user_batch(u, rng, 4).shape == (4, 3)


def test_dirichlet_partition_deterministic_under_seed():
    data, labels = _toy_labeled()
    a = dirichlet_partition(data, labels, 4, alpha=0.3, seed=11)
    b = dirichlet_partition(data, labels, 4, alpha=0.3, seed=11)
    c = dirichlet_partition(data, labels, 4, alpha=0.3, seed=12)
    assert a.meta["shard_sizes"] == b.meta["shard_sizes"]
    assert a.meta["label_hist"] == b.meta["label_hist"]
    for u in range(4):
        np.testing.assert_array_equal(
            a.user_batch(u, np.random.default_rng(5), 16),
            b.user_batch(u, np.random.default_rng(5), 16))
    # a different seed produces a different split (overwhelmingly likely)
    assert a.meta["shard_sizes"] != c.meta["shard_sizes"]


def test_dirichlet_partition_low_alpha_skews_labels():
    """alpha -> 0 concentrates each class on few users: per-user label
    histograms must be far from uniform."""
    data, labels = _toy_labeled(n=1200)
    ds = dirichlet_partition(data, labels, 4, alpha=0.05, seed=0)
    hist = np.asarray(ds.meta["label_hist"], np.float64)  # (U, n_classes)
    frac = hist / np.maximum(hist.sum(0, keepdims=True), 1)
    # for most classes one user owns the dominant share
    assert (frac.max(axis=0) > 0.8).mean() > 0.5


def test_quantity_skew_partition_sizes_and_determinism():
    data, _ = _toy_labeled()
    a = quantity_skew_partition(data, 6, alpha=0.2, seed=7)
    b = quantity_skew_partition(data, 6, alpha=0.2, seed=7)
    assert a.meta["shard_sizes"] == b.meta["shard_sizes"]
    sizes = np.asarray(a.meta["shard_sizes"])
    assert sizes.sum() == len(data) and sizes.min() >= 1
    # skew: the largest shard dominates the smallest at low alpha
    assert sizes.max() > 3 * sizes.min()


# ---------------------------------------------------------------------------
# CohortStore gather/scatter
# ---------------------------------------------------------------------------

def test_cohort_store_gather_scatter_roundtrip_identity():
    fcfg = DistGANConfig(num_users=5)
    st = init_state(PAIR, fcfg, jax.random.key(0))
    dl, ol = d_flat_layout(PAIR), d_opt_flat_layout(PAIR, fcfg)
    store = make_cohort_store(st.ds, st.d_opts, dl, ol)
    assert store.d_flat.shape == (5, dl.n)
    assert store.opt_flat.shape == (5, ol.n)

    idx = jnp.asarray([3, 0, 4])
    ds_c, opts_c = cohort_gather(store, idx, dl, ol)
    # gathered rows == the stacked trees' rows, leaf by leaf
    for leaf_c, leaf_full in zip(jax.tree.leaves(ds_c),
                                 jax.tree.leaves(st.ds)):
        np.testing.assert_array_equal(np.asarray(leaf_c),
                                      np.asarray(leaf_full)[np.asarray(idx)])

    # scatter the SAME slices back: the store must be bit-identical
    # (int optimizer leaves included — they round-trip through f32 rows)
    back = cohort_scatter(store, idx, ds_c, opts_c,
                          store.last_round[np.asarray(idx)][0], dl, ol)
    np.testing.assert_array_equal(np.asarray(back.d_flat),
                                  np.asarray(store.d_flat))
    np.testing.assert_array_equal(np.asarray(back.opt_flat),
                                  np.asarray(store.opt_flat))


def test_cohort_scatter_touches_only_cohort_rows_and_stamps_round():
    fcfg = DistGANConfig(num_users=4)
    st = init_state(PAIR, fcfg, jax.random.key(1))
    dl, ol = d_flat_layout(PAIR), d_opt_flat_layout(PAIR, fcfg)
    store = make_cohort_store(st.ds, st.d_opts, dl, ol)
    idx = jnp.asarray([1, 3])
    ds_c, opts_c = cohort_gather(store, idx, dl, ol)
    ds_c = jax.tree.map(lambda x: x + 1.0, ds_c)
    new = cohort_scatter(store, idx, ds_c, opts_c, jnp.int32(9), dl, ol)
    d_old = np.asarray(store.d_flat)
    d_new = np.asarray(new.d_flat)
    np.testing.assert_array_equal(d_new[[0, 2]], d_old[[0, 2]])
    np.testing.assert_allclose(d_new[[1, 3]], d_old[[1, 3]] + 1.0,
                               rtol=0, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(new.last_round), [0, 9, 0, 9])


# ---------------------------------------------------------------------------
# participation schedulers
# ---------------------------------------------------------------------------

def test_schedulers_shapes_and_replacement_free():
    rng = np.random.default_rng(0)
    for name in ["uniform", "round_robin", "weighted"]:
        sched = make_schedule(name, num_users=10, cohort=4, rounds=25,
                              rng=rng, shard_sizes=list(range(1, 11)))
        assert sched.shape == (25, 4) and sched.dtype == np.int32
        assert sched.min() >= 0 and sched.max() < 10
        for row in sched:               # replacement-free rows
            assert len(set(row.tolist())) == 4


def test_full_scheduler_is_identity_permutation():
    sched = make_schedule("full", 6, 6, 3, np.random.default_rng(0))
    np.testing.assert_array_equal(sched, np.tile(np.arange(6), (3, 1)))
    with pytest.raises(AssertionError):
        make_schedule("full", 6, 3, 3, np.random.default_rng(0))


def test_round_robin_cycles_all_users():
    sched = make_schedule("round_robin", 8, 2, 8, np.random.default_rng(0))
    counts = np.bincount(sched.ravel(), minlength=8)
    np.testing.assert_array_equal(counts, np.full(8, 2))


def test_weighted_scheduler_prefers_large_shards():
    rng = np.random.default_rng(0)
    sizes = [1, 1, 1, 1, 100, 100]
    sched = make_schedule("weighted", 6, 2, 200, rng, shard_sizes=sizes)
    counts = np.bincount(sched.ravel(), minlength=6)
    assert counts[4] + counts[5] > 0.8 * sched.size


# ---------------------------------------------------------------------------
# staleness-aware combiners
# ---------------------------------------------------------------------------

def test_staleness_mean_reduces_to_mean_at_zero_age():
    d = jnp.asarray(np.random.default_rng(0).normal(size=(4, 9)),
                    jnp.float32)
    ages = jnp.zeros((4,), jnp.int32)
    np.testing.assert_allclose(
        np.asarray(combine_staleness_mean(d, ages, decay=0.5)),
        np.asarray(jnp.mean(d, axis=0)), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(combine_staleness_mean(d, None)),
        np.asarray(jnp.mean(d, axis=0)), rtol=1e-6)


def test_staleness_mean_downweights_stale_users():
    d = jnp.stack([jnp.ones((5,)), -jnp.ones((5,))])
    ages = jnp.asarray([0, 2], jnp.int32)      # user 1 is 2 rounds stale
    out = np.asarray(combine_staleness_mean(d, ages, decay=0.5))
    want = (1.0 * 1 + 0.25 * -1) / 1.25
    np.testing.assert_allclose(out, np.full(5, want), rtol=1e-6)


def test_staleness_mean_no_nan_for_uniformly_old_cohorts():
    """decay**age underflows to f32 zero near age ~150; the weights are
    computed relative to the youngest member so a uniformly-stale cohort
    (routine at large U/C) must not produce 0/0 = NaN."""
    d = jnp.asarray(np.random.default_rng(0).normal(size=(4, 7)),
                    jnp.float32)
    ages = jnp.asarray([500, 501, 502, 503], jnp.int32)
    out = np.asarray(combine_staleness_mean(d, ages, decay=0.5))
    assert np.all(np.isfinite(out))
    # shift invariance: same result as the equivalent small ages
    want = np.asarray(combine_staleness_mean(
        d, jnp.asarray([0, 1, 2, 3], jnp.int32), decay=0.5))
    np.testing.assert_allclose(out, want, rtol=1e-6)


def test_staleness_max_abs_handicaps_stale_large_delta():
    # stale user uploads |2.0|, fresh user |1.5|: with decay 0.5 and age 2
    # the stale entry competes as 0.5 — the fresh one must win
    d = jnp.asarray([[1.5, 0.0], [2.0, 0.0]], jnp.float32)
    ages = jnp.asarray([0, 2], jnp.int32)
    out = np.asarray(combine_staleness_max_abs(d, ages, decay=0.5))
    assert out[0] == 1.5
    assert COMBINERS["staleness_max_abs"].needs_ages


# ---------------------------------------------------------------------------
# end-to-end partial participation
# ---------------------------------------------------------------------------

def _ds(num_users):
    users, union = make_user_domains(num_users, 2, 1.0)
    return FederatedDataset([u.sample for u in users], union.sample,
                            {"shard_sizes": [100 * (u + 1)
                                             for u in range(num_users)]})


@pytest.mark.parametrize("participation", ["uniform", "round_robin",
                                           "weighted"])
def test_partial_participation_trains_and_reports(participation):
    U, C = 6, 2
    ds = _ds(U)
    fcfg = DistGANConfig(num_users=U, selection="topk", upload_frac=0.3,
                         combiner="staleness_max_abs")
    r = run_distgan(PAIR, fcfg, ds, "approach1", steps=12, batch_size=16,
                    seed=0, eval_samples=0, rounds_per_jit=4,
                    participation=participation, cohort_size=C)
    assert r.g_losses.shape == (12,)
    assert r.d_losses.shape == (12, C)
    assert np.all(np.isfinite(r.g_losses))
    counts = r.extra["participation_counts"]
    assert counts.sum() == 12 * C
    np.testing.assert_array_equal(
        counts, np.bincount(r.extra["schedule"].ravel(), minlength=U))
    assert r.extra["staleness"].shape == (U,)
    assert r.extra["mean_age"].shape == (12,)
    assert r.extra["cohort_size"] == C


def test_cohort_program_width_is_C_not_U():
    """The compiled cohort program is shaped by C alone: the same engine
    instance serves runs whose U differs, as long as C matches — i.e. no
    (U-dependent) retrace beyond the resident buffer shapes."""
    from repro.core.engine import make_cohort_engine, init_cohort_state
    C = 2
    fcfg16 = DistGANConfig(num_users=16, selection="topk", upload_frac=0.3)
    eng = make_cohort_engine(PAIR, fcfg16, "approach2")
    rng = np.random.default_rng(0)
    reals = jnp.asarray(rng.normal(size=(4, C, 8, 2)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 16, size=(4, C)).astype(np.int32))
    c = init_cohort_state(PAIR, fcfg16, jax.random.key(0))
    c, m = eng(c, reals, idx)
    assert np.asarray(m["d_loss"]).shape == (4, C)
    # traced shapes carry C, not U
    assert c.store.d_flat.shape[0] == 16


def test_baseline_rejects_cohorting():
    ds = _ds(2)
    with pytest.raises(ValueError):
        run_distgan(PAIR, DistGANConfig(), ds, "baseline", steps=2,
                    batch_size=8, eval_samples=0, participation="uniform")


# ---------------------------------------------------------------------------
# cohort-aware upload accounting (satellite): C uploads per round, not U
# ---------------------------------------------------------------------------

def test_upload_bytes_flat_prices_each_policy():
    n = 1000
    assert upload_bytes_flat(n, "none") == 4 * n
    assert upload_bytes_flat(n, "topk", 0.3) == 300 * 8
    assert upload_bytes_flat(n, "random", 0.3) == 300 * 8
    # shared_random ships values only (mask derived from a shared key)
    assert upload_bytes_flat(n, "shared_random", 0.3) == 300 * 4
    # threshold is data-dependent: the measured kept fraction is REQUIRED
    assert upload_bytes_flat(n, "threshold", kept_frac=0.5) == 500 * 8
    with pytest.raises(AssertionError):
        upload_bytes_flat(n, "threshold", 0.3)


def test_upload_bytes_flat_prices_compressed_payloads():
    """The codec reprices value bytes (bf16: 2B, int8: 1B + one 4B scale
    per row); index bytes are selection-policy property, untouched."""
    n = 1000
    # dense rows: value width scales, int8 adds the scale
    assert upload_bytes_flat(n, "none", codec="bf16") == 2 * n
    assert upload_bytes_flat(n, "none", codec="int8") == 1 * n + 4
    # sparse rows: 4B int32 index + codec-width value per kept entry
    assert upload_bytes_flat(n, "topk", 0.3, codec="bf16") == 300 * 6
    assert upload_bytes_flat(n, "topk", 0.3, codec="int8") == 300 * 5 + 4
    assert upload_bytes_flat(n, "topk", 0.3,
                             codec="topk_int8") == 300 * 5 + 4
    assert upload_bytes_flat(n, "threshold", kept_frac=0.5,
                             codec="topk_int8") == 500 * 5 + 4
    # shared_random ships values only — codec still shrinks them
    assert upload_bytes_flat(n, "shared_random", 0.3,
                             codec="bf16") == 300 * 2
    # topk+int8 at equal kept fraction vs dense f32 coordinates:
    # 8B -> 5B per kept entry, and the ISSUE's gated 3.5x comes from
    # comparing against the DENSE f32 row (4n vs kept*5+4)
    dense = upload_bytes_flat(n, "none")
    compressed = upload_bytes_flat(n, "topk", 0.1, codec="topk_int8")
    assert dense / compressed >= 3.5


def test_priced_bytes_match_packed_payload():
    """The pricing table must equal the nbytes of the REAL packed wire
    buffers (int32 indices + codec-encoded values + per-row scale)."""
    from repro.core.federated import packed_payload_nbytes, select_delta_flat

    n = 1024
    rng = np.random.default_rng(7)
    row = jnp.asarray(rng.normal(size=n).astype(np.float32))
    # top-k masking: the real kept count equals int(n*frac) (no ties on
    # continuous data), so priced == packed for every codec
    for frac in [0.1, 0.25]:
        masked, _ = select_delta_flat(row, "topk", frac=frac)
        for codec in ["none", "bf16", "int8", "topk_int8"]:
            priced = upload_bytes_flat(n, "topk", frac, codec=codec)
            real = packed_payload_nbytes(np.asarray(masked), "topk", codec)
            assert priced == real, ("topk", codec, priced, real)
    # dense rows ship every coordinate, valueless of sparsity
    for codec in ["none", "bf16", "int8"]:
        priced = upload_bytes_flat(n, "none", codec=codec)
        real = packed_payload_nbytes(np.asarray(row), "none", codec)
        assert priced == real, ("none", codec, priced, real)
    # random/shared_random keep a BINOMIAL count; the table prices the
    # expectation — assert on a row with exactly int(n*frac) survivors
    k = int(n * 0.25)
    sparse = np.zeros(n, np.float32)
    sparse[rng.choice(n, size=k, replace=False)] = rng.normal(size=k)
    for policy in ["random", "shared_random"]:
        for codec in ["none", "bf16", "int8"]:
            priced = upload_bytes_flat(n, policy, 0.25, codec=codec)
            real = packed_payload_nbytes(sparse, policy, codec)
            assert priced == real, (policy, codec, priced, real)
    # threshold: price with the MEASURED kept fraction
    masked, kept = select_delta_flat(row, "threshold", tau=1.0)
    priced = upload_bytes_flat(n, "threshold", kept_frac=float(kept),
                               codec="int8")
    real = packed_payload_nbytes(np.asarray(masked), "threshold", "int8")
    assert priced == real


def test_run_distgan_reports_cohort_scaled_upload_bytes():
    """A U=6, C=2 run must account 2 uploads per round — the scheduled
    cohort — not 6."""
    U, C = 6, 2
    ds = _ds(U)
    fcfg = DistGANConfig(num_users=U, selection="topk", upload_frac=0.3)
    r = run_distgan(PAIR, fcfg, ds, "approach1", steps=6, batch_size=16,
                    seed=0, eval_samples=0, participation="uniform",
                    cohort_size=C)
    n = d_flat_layout(PAIR).n
    per_user = int(n * 0.3) * 8
    assert r.extra["upload_bytes_per_user"] == per_user
    assert r.extra["upload_bytes_per_round"] == C * per_user
    # full participation accounts all U users
    rf = run_distgan(PAIR, fcfg, ds, "approach1", steps=4, batch_size=16,
                     seed=0, eval_samples=0)
    assert rf.extra["upload_bytes_per_round"] == U * per_user
    # approaches without parameter uploads don't report the key
    r2 = run_distgan(PAIR, DistGANConfig(num_users=U), ds, "approach2",
                     steps=4, batch_size=16, seed=0, eval_samples=0,
                     participation="uniform", cohort_size=C)
    assert "upload_bytes_per_round" not in r2.extra


# ---------------------------------------------------------------------------
# participation-adaptive combine weights (satellite)
# ---------------------------------------------------------------------------

def test_participation_weights_favor_under_participants():
    """A user drawn less often than the uniform expectation gets a larger
    weight; each round is mean-1 normalized; round 0 is all-ones."""
    # user 0 appears every round, users 1..3 rotate in the second slot
    sched = np.asarray([[0, 1], [0, 2], [0, 3], [0, 1], [0, 2]], np.int32)
    w = participation_weights(sched, num_users=4)
    assert w.shape == (5, 2) and w.dtype == np.float32
    np.testing.assert_allclose(w[0], [1.0, 1.0])
    np.testing.assert_allclose(w.mean(axis=1), np.ones(5), rtol=1e-6)
    # from round 1 on, the over-participating user 0 weighs LESS than the
    # rotating under-participants
    assert np.all(w[1:, 0] < w[1:, 1])
    # and the gap grows with the imbalance
    assert w[4, 0] < w[1, 0]


def test_adaptive_server_scale_end_to_end():
    """Opt-in combiner option: device and host backends agree (to the
    usual 1-ULP scan-vs-standalone tiling — tests/test_stream.py), the
    weights are reported, and the trajectory genuinely differs from the
    non-adaptive run (the weighted fold changes the server updates)."""
    U, C = 6, 2
    ds = _ds(U)
    fcfg = DistGANConfig(num_users=U, selection="topk", upload_frac=0.3)
    kw = dict(steps=8, batch_size=16, seed=0, eval_samples=0,
              participation="weighted", cohort_size=C)
    r_dev = run_distgan(PAIR, fcfg, ds, "approach1",
                        adaptive_server_scale=True, **kw)
    r_host = run_distgan(PAIR, fcfg, ds, "approach1", state_backend="host",
                         adaptive_server_scale=True, **kw)
    r_plain = run_distgan(PAIR, fcfg, ds, "approach1", **kw)
    np.testing.assert_allclose(r_dev.g_losses, r_host.g_losses,
                               rtol=0, atol=1e-6)
    assert r_dev.extra["adaptive_server_scale"]
    assert r_dev.extra["participation_weights"].shape == (8, C)
    assert not np.array_equal(r_dev.g_losses, r_plain.g_losses)
    assert np.all(np.isfinite(r_dev.g_losses))


def test_adaptive_server_scale_requires_uploads_and_cohort():
    ds = _ds(4)
    with pytest.raises(ValueError):
        run_distgan(PAIR, DistGANConfig(num_users=4), ds, "approach2",
                    steps=2, batch_size=8, eval_samples=0,
                    participation="uniform", cohort_size=2,
                    adaptive_server_scale=True)
    with pytest.raises(ValueError):
        run_distgan(PAIR, DistGANConfig(num_users=4), ds, "approach1",
                    steps=2, batch_size=8, eval_samples=0,
                    adaptive_server_scale=True)


# ---------------------------------------------------------------------------
# padded-with-mask remainder chunks x partial cohorts (satellite)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("approach", ["approach1", "approach2", "approach3"])
def test_remainder_chunk_with_partial_cohort_is_invariant(approach):
    """steps % rounds_per_jit != 0 while C < U: the padded-and-masked
    trailing chunk must not perturb the trajectory — a run chunked 4+4+2
    (padded) is bitwise the run chunked 5+5 (exact)."""
    U, C = 6, 2
    ds = _ds(U)
    fcfg = DistGANConfig(num_users=U, selection="topk", upload_frac=0.3)
    kw = dict(steps=10, batch_size=16, seed=0, eval_samples=0,
              participation="round_robin", cohort_size=C)
    r_pad = run_distgan(PAIR, fcfg, ds, approach, rounds_per_jit=4, **kw)
    r_exact = run_distgan(PAIR, fcfg, ds, approach, rounds_per_jit=5, **kw)
    np.testing.assert_array_equal(r_pad.g_losses, r_exact.g_losses)
    np.testing.assert_array_equal(r_pad.d_losses, r_exact.d_losses)
    assert r_pad.d_losses.shape == (10, C)


def test_spmd_cohort_remainder_chunk_masked_pad():
    """The SPMD cohort engine under a padded+masked remainder chunk (C < U
    on 4 devices): padded rounds never touch the carry — two chunk splits
    of the same 6 rounds agree with the single-chunk reference."""
    import os
    import subprocess
    import sys
    import textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.gan import make_mlp_pair, MLPGanConfig
        from repro.core.approaches import DistGANConfig
        from repro.core.engine import (init_cohort_state,
                                       make_spmd_cohort_engine)
        from repro.core.federated import make_schedule
        from repro.launch.mesh import make_users_mesh

        C, U, K = 4, 8, 6
        pair = make_mlp_pair(MLPGanConfig(data_dim=2, z_dim=8, g_hidden=16,
                                          d_hidden=16))
        mesh = make_users_mesh(C)
        rng = np.random.default_rng(0)
        reals = rng.normal(size=(K, C, 16, 2)).astype(np.float32)
        sched = make_schedule("round_robin", U, C, K,
                              np.random.default_rng(1))
        fcfg = DistGANConfig(num_users=U, selection="topk", upload_frac=0.3)
        eng = make_spmd_cohort_engine(pair, fcfg, mesh, "approach1", C)

        def pad(a, k):
            fill = np.broadcast_to(a[-1:], (k - a.shape[0],) + a.shape[1:])
            return np.concatenate([a, fill], 0)

        # reference: one unmasked chunk of all 6 rounds
        c_ref = init_cohort_state(pair, fcfg, jax.random.key(0),
                                  sync_ds=True)
        c_ref, m_ref = eng(c_ref, jnp.asarray(reals), jnp.asarray(sched))

        # padded: chunks of 4 -> rounds 0-3, then 4-5 padded to 4 + mask
        c = init_cohort_state(pair, fcfg, jax.random.key(0), sync_ds=True)
        gl = []
        for start, k in [(0, 4), (4, 2)]:
            rs = jnp.asarray(pad(reals[start:start + 4], 4))
            ix = jnp.asarray(pad(sched[start:start + 4], 4))
            valid = jnp.asarray(np.arange(4) < k)
            c, m = eng(c, rs, ix, valid=valid)
            gl.append(np.asarray(m["g_loss"])[:k])
        np.testing.assert_array_equal(np.asarray(m_ref["g_loss"]),
                                      np.concatenate(gl))
        np.testing.assert_array_equal(np.asarray(c_ref.store.d_flat),
                                      np.asarray(c.store.d_flat))
        np.testing.assert_array_equal(np.asarray(c_ref.store.last_round),
                                      np.asarray(c.store.last_round))
        print("SPMD PAD OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=560)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "SPMD PAD OK" in r.stdout
