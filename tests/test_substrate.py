"""Substrate tests: optimizers, schedules, checkpointing, sharding rules,
data pipeline."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

st = pytest.importorskip("hypothesis.strategies")
from hypothesis import given, settings

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data.synthetic import TokenStream, synthetic_batch_for
from repro.optim import adamw, apply_updates, cosine_schedule, global_norm_clip, sgd
from repro.sharding.rules import DEFAULT_RULES, logical_to_spec


# ---------------------------------------------------------------------------
# optim
# ---------------------------------------------------------------------------

def test_adamw_minimizes_quadratic():
    opt = adamw(0.1)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_sgd_momentum_minimizes():
    opt = sgd(0.05, momentum=0.9)
    params = {"w": jnp.asarray([4.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
    assert float(jnp.abs(params["w"])[0]) < 5e-2


def test_optimizer_state_is_f32_for_bf16_params():
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    opt = adamw(1e-3)
    st_ = opt.init(params)
    assert st_["mu"]["w"].dtype == jnp.float32


def test_global_norm_clip():
    g = {"a": jnp.asarray([3.0, 4.0])}  # norm 5
    clipped, norm = global_norm_clip(g, 1.0)
    assert abs(float(norm) - 5.0) < 1e-5
    cn = float(jnp.linalg.norm(clipped["a"]))
    assert abs(cn - 1.0) < 1e-5


def test_cosine_schedule_shape():
    s = cosine_schedule(1.0, 10, 100)
    assert float(s(0)) == 0.0
    assert abs(float(s(10)) - 1.0) < 1e-6
    assert float(s(100)) < float(s(50)) < 1.0


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": (jnp.ones((4,), jnp.bfloat16) * 1.5),
                  "d": jnp.asarray(3, jnp.int32)}}
    save_checkpoint(str(tmp_path), 7, tree)
    assert latest_step(str(tmp_path)) == 7
    out = restore_checkpoint(str(tmp_path), 7, tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"a": jnp.zeros((2,))})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), 1, {"a": jnp.zeros((3,))})


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def _mesh44():
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


@given(st.integers(1, 4096))
@settings(deadline=None, max_examples=40)
def test_divisibility_fallback_never_invalid(dim):
    """For any dim, the derived spec either divides it or replicates."""
    import os
    mesh = jax.make_mesh((1,), ("model",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    spec = logical_to_spec(("ffn",), (dim,), mesh)
    # model axis of size 1 never shards (total==1 -> replicate)
    assert spec == jax.sharding.PartitionSpec(None)


def test_rules_on_production_shapes():
    """Run the actual derivation on a 16x16 mesh in a subprocess (needs
    256 host devices) and assert the awkward dims fall back correctly."""
    import subprocess, sys, textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        import jax
        from jax.sharding import PartitionSpec as PS
        from repro.sharding.rules import logical_to_spec
        mesh = jax.make_mesh((16, 16), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        # yi-34b: 56 heads don't divide 16 -> replicated; d_ff 20480 shards
        assert logical_to_spec(("embed", "heads", None), (7168, 56, 128),
                               mesh) == PS(None, None, None)
        assert logical_to_spec(("embed", "ffn"), (7168, 20480),
                               mesh) == PS(None, "model")
        # mamba2: vocab 50280 not divisible -> embed_alt picks up model
        assert logical_to_spec(("vocab", "embed_alt"), (50280, 1536),
                               mesh) == PS(None, "model")
        # divisible vocab keeps model on vocab, embed_alt replicates
        assert logical_to_spec(("vocab", "embed_alt"), (32000, 2048),
                               mesh) == PS("model", None)
        # batch over combined (pod,data)
        mesh3 = jax.make_mesh((2, 16, 16), ("pod", "data", "model"),
                              axis_types=(jax.sharding.AxisType.Auto,)*3)
        assert logical_to_spec(("batch", None), (256, 4096),
                               mesh3) == PS(("pod", "data"), None)
        # batch=1 (long_500k) replicates
        assert logical_to_spec(("batch", None), (1, 8192), mesh3) == \\
            PS(None, None)
        print("OK")
    """)
    r = _run_sub(code)
    assert "OK" in r.stdout, r.stdout + r.stderr


def _run_sub(code):
    import subprocess, sys
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    return subprocess.run([__import__("sys").executable, "-c", code],
                          capture_output=True, text=True, env=env)


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_token_stream_deterministic_and_learnable():
    s1 = TokenStream(100, 16, 4, seed=3)
    s2 = TokenStream(100, 16, 4, seed=3)
    b1, b2 = s1.batch(5), s2.batch(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    # targets are next tokens
    np.testing.assert_array_equal(np.asarray(b1["tokens"][:, 1:]),
                                  np.asarray(b1["targets"][:, :-1]))


def test_synthetic_batch_audio_shape():
    from repro.configs.base import get_config
    cfg = get_config("seamless-m4t-medium").reduced()
    b = synthetic_batch_for(cfg, 3, 32)
    assert b["src_embeds"].shape == (3, 8, cfg.d_model)
